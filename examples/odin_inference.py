"""End-to-end ODIN inference: train CNN1 on synthetic digits, quantize to
8-bit, run inference in all three execution modes (fp32 / int8 / bit-faithful
stochastic), and report the accuracy gaps + the PCRAM execution cost.

This is the paper's core experiment (Table 2 accuracy column + Fig. 6 cost)
on the offline-synthesizable stand-in task (DESIGN.md §6.4: we validate the
quantization/SC *gap*, not absolute MNIST numbers).

    PYTHONPATH=src python examples/odin_inference.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.odin_linear import OdinConfig
from repro.data.synthetic import digits_batch
from repro.nn.cnn import RUNNABLE_CNN1, cnn_forward, cnn_loss, cnn_param_spec
from repro.nn.module import materialize
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.pim.geometry import OdinModule
from repro.pim.trace import trace_topology


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--sc-eval-batches", type=int, default=2)
    args = ap.parse_args()

    topo = RUNNABLE_CNN1
    params = materialize(cnn_param_spec(topo), jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(moment_dtype="float32", weight_decay=0.0)
    opt = adamw_init(params, opt_cfg)

    @jax.jit
    def step(params, opt, batch):
        (loss, m), g = jax.value_and_grad(cnn_loss, has_aux=True)(params, batch, topo)
        params, opt = adamw_update(g, params, opt, 1e-3, opt_cfg)
        return params, opt, m

    print(f"== training CNN1 ({args.steps} steps on synthetic digits)")
    t0 = time.time()
    for i in range(args.steps):
        batch = digits_batch(0, i, batch=args.batch)
        params, opt, m = step(params, opt, batch)
        if i % 50 == 0 or i == args.steps - 1:
            print(f"   step {i:4d}  loss {float(m['loss']):.3f}  "
                  f"acc {float(m['acc']):.3f}")
    print(f"   trained in {time.time()-t0:.1f}s")

    def evaluate(odin, n_batches, bs=64):
        correct = total = 0
        for i in range(n_batches):
            b = digits_batch(1, 10_000 + i, batch=bs)
            logits = cnn_forward(params, b["image"], topo, odin=odin)
            correct += int((jnp.argmax(logits, -1) == b["label"]).sum())
            total += bs
        return correct / total

    print("== held-out accuracy per execution mode")
    acc_fp = evaluate(None, 8)
    acc_i8 = evaluate(OdinConfig(mode="int8", signed_activations=True), 8)
    # hybrid SC: per-block MUX subtree + popcount + binary accumulate; the
    # block size is the position of ODIN's hybrid binary/stochastic boundary
    # (32 = the PCRAM row/command operand granularity)
    nb, bs = args.sc_eval_batches, 16
    acc_sc32 = evaluate(OdinConfig(mode="sc", signed_activations=False, sc_block_k=32), nb, bs)
    acc_sc8 = evaluate(OdinConfig(mode="sc", signed_activations=False, sc_block_k=8), nb, bs)
    # naive full-tree SC: one MUX tree over all K inputs — at K=784 the
    # 1/K̂ stream subsampling destroys the signal (documented finding)
    acc_sc_full = evaluate(OdinConfig(mode="sc", signed_activations=True, sc_block_k=0),
                           1, bs=16)
    print(f"   fp32           : {acc_fp:.3f}")
    print(f"   int8           : {acc_i8:.3f}   (gap {100*(acc_fp-acc_i8):+.1f} pp)")
    print(f"   sc (hybrid/32) : {acc_sc32:.3f}   (gap {100*(acc_fp-acc_sc32):+.1f} pp — "
          f"paper's row granularity)")
    print(f"   sc (hybrid/8)  : {acc_sc8:.3f}   (gap {100*(acc_fp-acc_sc8):+.1f} pp — "
          f"finer popcount boundary)")
    print(f"   sc (full tree) : {acc_sc_full:.3f}   (collapses at K=784 — a 256-bit "
          f"stream cannot survive a 1024-deep MUX tree)")
    print("   ⇒ the hybrid-boundary position is THE accuracy/energy knob: the "
          "paper's 'minimal loss' claim needs popcounts at ≤32-operand blocks.")

    print("== in-situ PCRAM cost for one inference (transaction model)")
    cost = trace_topology(topo, OdinModule())
    print(f"   latency {cost.total_latency_ns/1e3:.1f} µs, "
          f"energy {cost.total_energy_pj/1e9:.3f} mJ, "
          f"MACs {cost.total_macs/1e3:.0f}k")


if __name__ == "__main__":
    main()
