"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps with
checkpoint-restart fault tolerance (deliverable b).

Uses a 4-layer, d=512 dense transformer (phi4-family block) on the
deterministic synthetic token task; loss should fall from ~ln(V) toward ~1
within a few hundred steps.  Interrupt it and re-run with --resume to see
exact continuation.

    PYTHONPATH=src python examples/train_lm.py --steps 300 [--resume]
"""
import argparse

from repro.configs.base import AttnConfig, BlockConfig, ModelConfig
from repro.launch.train import train_loop
from repro.optim.adamw import AdamWConfig

# ~100M params: 12L × d=768, 12 heads, GQA kv=4, SwiGLU ff=2048, vocab 8192
CFG_100M = ModelConfig(
    name="demo-100m",
    d_model=768,
    vocab=8_192,
    blocks=(
        BlockConfig(
            kind="dense", n_layers=12,
            attn=AttnConfig(kind="gqa", n_heads=12, n_kv_heads=4, d_head=64),
            d_ff=2_048,
        ),
    ),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_100m")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--int8-moments", action="store_true",
                    help="8-bit optimizer states (the paper's 8-bit theme)")
    args = ap.parse_args()

    opt_cfg = AdamWConfig(moment_dtype="int8" if args.int8_moments else "float32")
    state, losses = train_loop(
        CFG_100M, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, resume=args.resume, save_every=50,
        opt_cfg=opt_cfg, base_lr=1e-3, log_every=20,
    )
    print(f"first-10 mean loss {sum(losses[:10])/max(len(losses[:10]),1):.3f} → "
          f"last-10 mean loss {sum(losses[-10:])/max(len(losses[-10:]),1):.3f}")


if __name__ == "__main__":
    main()
