"""Quickstart: ODIN stochastic arithmetic in five minutes.

Runs the paper's full pipeline on one dot product and one matmul:
binary → stochastic (LUT) → AND multiply → MUX-tree accumulate → popcount,
then shows the three execution modes of the drop-in `odin_linear` layer and
the PCRAM cost of running it in-situ.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stochastic as sc
from repro.core.odin_linear import OdinConfig, get_luts, odin_linear
from repro.pim.commands import command_set
from repro.pim.geometry import OdinModule
from repro.pim.trace import FC, Topology, trace_topology

spec = sc.StreamSpec(stream_len=256, n_levels=256)
lut_a, lut_w, selects = get_luts(256, 256, 0)

print("== 1. one multiply, the ODIN way (paper Fig. 2a)")
a, b = 96, 200                                # 8-bit operands
sa = sc.b_to_s(jnp.int32(a), lut_a)           # 256-bit stream, density a/256
sb = sc.b_to_s(jnp.int32(b), lut_w)           # decorrelated LUT!
prod = sc.sc_mul(sa, sb)                      # bit-parallel AND
pop = int(sc.s_to_b(prod))                    # popcount (S_TO_B)
print(f"   a={a} b={b}:  popcount(AND)={pop}  vs  a*b/256={a*b/256:.1f}")

print("== 2. a stochastic matmul vs its deterministic expectation")
rng = np.random.default_rng(0)
A = jnp.asarray(rng.integers(0, 256, (4, 16)), jnp.int32)
W = jnp.asarray(rng.integers(0, 256, (16, 3)), jnp.int32)
pops = sc.sc_matmul(A, W, lut_a, lut_w, selects, spec)
exp = sc.expected_matmul(A, W, spec)
print(f"   max |sc - E[sc]| = {float(jnp.abs(pops - exp).max()):.1f} popcounts "
      f"(stream noise)")

print("== 3. odin_linear: exact | int8 (MXU surrogate) | sc (bit-faithful)")
x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (2, 32)))
w = jax.random.normal(jax.random.PRNGKey(2), (32, 4)) * 0.4
for mode in ("exact", "int8", "sc"):
    y = odin_linear(x, w, OdinConfig(mode=mode, signed_activations=False))
    print(f"   {mode:5s}: {np.asarray(y[0])}")

print("== 4. what would this cost inside PCRAM? (paper Table 1 model)")
topo = Topology("demo", [FC(32, 4)])
cost = trace_topology(topo, OdinModule())
cmds = cost.layers[0].commands
print(f"   commands: {cmds}")
print(f"   latency {cost.total_latency_ns:.0f} ns, energy {cost.total_energy_pj/1e3:.1f} nJ "
      f"(in-situ — zero operand movement to a CPU)")
