"""Batched KV-cache serving example (deliverable b, serving flavor).

Prefills a batch of synthetic prompts through a smoke-size config of any
assigned architecture and decodes greedily — the same prefill/decode step
functions the production dry-run lowers at decode_32k / long_500k.

    PYTHONPATH=src python examples/serve_lm.py --arch hymba-1.5b
"""
import argparse

import numpy as np

from repro.launch.serve import serve
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b", choices=registry.ARCH_IDS)
    ap.add_argument("--full", action="store_true",
                    help="full config (CPU: slow!) instead of smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch) if args.full else registry.get_smoke(args.arch)
    generated, tps = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                           gen=args.gen)
    print(f"arch={args.arch} ({'full' if args.full else 'smoke'})")
    for i in range(min(args.batch, 3)):
        print(f"  request {i}: {np.asarray(generated)[i].ravel()[:20]}")


if __name__ == "__main__":
    main()
