"""Continuous-batching serving example (deliverable b, serving flavor).

Streams a mixed-length synthetic workload through the serving engine
(repro.serving): requests arrive open-loop, admit into cache slots, prefill
in chunks, decode in one fixed-shape [slots, 1] step, and retire — freed
slots immediately re-admit queued work.  Per-request TTFT/TPOT and the ODIN
PIMC energy bill are printed at the end.

    PYTHONPATH=src python examples/serve_lm.py --arch hymba-1.5b --scenario mixed

With ``--listen`` the engine instead serves live HTTP clients through the
asyncio front door (bounded queue, per-tenant quotas, SSE streaming):

    PYTHONPATH=src python examples/serve_lm.py --listen --port 8080 &
    curl -N -X POST http://127.0.0.1:8080/generate \\
        -d '{"prompt_len": 32, "max_new": 16, "tenant": "alice"}'
    # → data: {"kind": "token", "rid": 0, "token": [1234], ...}
    #   data: {"kind": "done", "rid": 0, "state": "done", ...}
"""
import argparse
import asyncio
import dataclasses

import numpy as np

from repro.models import registry
from repro.serving import SCENARIOS, ServingEngine, make_requests


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b", choices=registry.ARCH_IDS)
    ap.add_argument("--full", action="store_true",
                    help="full config (CPU: slow!) instead of smoke")
    ap.add_argument("--scenario", default="mixed", choices=sorted(SCENARIOS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--horizon", type=int, default=1,
                    help="max decode steps fused into one dispatch")
    ap.add_argument("--spec-ngram", type=int, default=0, metavar="K",
                    help="n-gram self-speculative decode draft length (0 = off)")
    ap.add_argument("--no-mixed", action="store_true",
                    help="disable the fused mixed prefill+decode dispatch "
                         "(auto-enabled for fully paged models)")
    ap.add_argument("--mixed-budget", type=int, default=None,
                    help="query-row budget per mixed dispatch "
                         "(default: chunk + slots)")
    ap.add_argument("--odin-mode", choices=["exact", "int8", "sc"], default=None)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline after arrival (TIMEOUT past it)")
    ap.add_argument("--queue-timeout-ms", type=float, default=None,
                    help="max queue wait before admission")
    ap.add_argument("--degrade", action="store_true",
                    help="enable the graceful-degradation ladder")
    ap.add_argument("--listen", action="store_true",
                    help="serve live HTTP clients (POST /generate, SSE "
                         "streaming) instead of the synthetic workload")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--max-queue", type=int, default=32,
                    help="waiting-queue bound; beyond it clients get 429 + "
                         "Retry-After")
    ap.add_argument("--tenant-rate", type=float, default=None,
                    help="per-tenant emitted-token quota (tokens/s)")
    args = ap.parse_args()

    cfg = registry.get_config(args.arch) if args.full else registry.get_smoke(args.arch)

    if args.listen:
        from repro.serving.frontdoor import FrontDoor, run_server
        engine = ServingEngine(cfg, slots=args.slots, max_len=128,
                               block_size=16, odin_mode=args.odin_mode,
                               mixed=False if args.no_mixed else None,
                               mixed_budget=args.mixed_budget,
                               horizon=args.horizon,
                               spec_ngram=args.spec_ngram,
                               degrade=args.degrade)
        fd = FrontDoor(engine, max_queue=args.max_queue,
                       tenant_rate=args.tenant_rate, heartbeat_s=0.5)
        print(f"listening on http://{args.host}:{args.port}/generate "
              f"(curl -N -X POST ... -d '{{\"prompt_len\": 32}}'); "
              f"SIGTERM/SIGINT drain gracefully")
        try:
            asyncio.run(run_server(fd, args.host, args.port, vocab=cfg.vocab))
        except KeyboardInterrupt:
            pass
        s = engine.summary()
        print(f"drained: terminal {s['terminal']}, front door {fd.summary()}")
        return

    spec = dataclasses.replace(SCENARIOS[args.scenario], n_requests=args.requests)
    max_len = max(spec.prompt_buckets) + spec.shared_prefix + max(spec.gen_buckets)
    max_len = -(-max_len // 16) * 16

    streamed = {}

    def on_token(req, tok, now):
        streamed.setdefault(req.rid, []).append(int(np.asarray(tok).ravel()[0]))

    engine = ServingEngine(cfg, slots=args.slots, max_len=max_len,
                           block_size=16, odin_mode=args.odin_mode,
                           mixed=False if args.no_mixed else None,
                           mixed_budget=args.mixed_budget,
                           horizon=args.horizon, spec_ngram=args.spec_ngram,
                           deadline_s=(args.deadline_ms / 1e3
                                       if args.deadline_ms is not None else None),
                           queue_timeout_s=(args.queue_timeout_ms / 1e3
                                            if args.queue_timeout_ms is not None
                                            else None),
                           degrade=args.degrade,
                           on_token=on_token)
    summary = engine.run(make_requests(cfg, spec, seed=0))
    term = summary["terminal"]
    if term["timeout"] or term["cancelled"] or term["failed"]:
        print(f"terminal: {term}")

    print(f"arch={args.arch} ({'full' if args.full else 'smoke'}) "
          f"scenario={args.scenario}: {summary['generated_tokens']} tokens, "
          f"{summary['decode_tokens_per_s']:.1f} tok/s decode "
          f"({summary['tokens_per_dispatch']:.1f} tok/dispatch, "
          f"accept_rate {summary['speculation']['accept_rate']:.2f}), "
          f"occupancy {summary['slot_occupancy']:.2f}")
    print(f"TTFT p50/p90 = {summary['ttft_s']['p50']*1e3:.0f}/{summary['ttft_s']['p90']*1e3:.0f} ms, "
          f"TPOT p50/p90 = {summary['tpot_s']['p50']*1e3:.1f}/{summary['tpot_s']['p90']*1e3:.1f} ms")
    for rec in summary["requests"][:3]:
        toks = streamed.get(rec["rid"], [])[:10]
        print(f"  request {rec['rid']}: prompt {rec['prompt_tokens']:3d} "
              f"gen {rec['generated_tokens']:3d} "
              f"odin {rec['odin']['energy_mj']:8.2f} mJ  tokens {toks}")


if __name__ == "__main__":
    main()
